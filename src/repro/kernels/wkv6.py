"""Chunked RWKV-6 WKV Pallas kernel (TPU adaptation of the Finch recurrence).

The GPU reference implementation of RWKV-6 is a per-timestep CUDA recurrence
(one thread per channel).  That shape is hostile to the MXU, so we use the
chunk-parallel form (DESIGN.md §Hardware-adaptation): split time into chunks
of C steps; within a chunk the data-dependent diagonal decay telescopes into

    P[t, s] = (r_t ⊙ e^{lc_t})ᵀ (k_s ⊙ e^{-lc_{s+1}}),   lc = cumsum(log w),

so the intra-chunk part is two dense matmuls (MXU work), and the cross-chunk
part carries a (K, V) state in VMEM scratch across the sequential TPU grid.

Grid: (BH, T/C) — chunk index innermost, so the state scratch persists
across the chunks of one (batch·head) and resets when a new head starts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_pallas"]


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                 chunk: int):
    @pl.when(pl.program_id(1) == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)            # (C, K)
    k = k_ref[0].astype(jnp.float32)            # (C, K)
    v = v_ref[0].astype(jnp.float32)            # (C, V)
    w = w_ref[0].astype(jnp.float32)            # (C, K), decay in (0, 1)
    u = u_ref[...].astype(jnp.float32)          # (1, K) bonus

    lw = jnp.log(jnp.maximum(w, 1e-12))
    lc = jnp.cumsum(lw, axis=0)                  # lc_t = Σ_{τ<=t} log w_τ
    lc_prev = lc - lw                            # Σ_{τ<t} log w_τ

    r_dec = r * jnp.exp(lc_prev)                 # r_t ⊙ e^{lc_{t-1}}
    k_grow = k * jnp.exp(-lc)                    # k_s ⊙ e^{-lc_s}

    # Intra-chunk: strict-causal pairwise decays, then one (C,C)@(C,V) matmul.
    p = jnp.dot(r_dec, k_grow.T, preferred_element_type=jnp.float32)  # (C, C)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    p = jnp.where(t_idx > s_idx, p, 0.0)
    o = jnp.dot(p, v, preferred_element_type=jnp.float32)            # (C, V)

    # Same-timestep bonus path: o_t += (r_t ⊙ u ⊙ k_t) summed · v_t.
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)                # (C, 1)
    o = o + bonus * v

    # Cross-chunk carry: o_t += (r_t ⊙ e^{lc_{t-1}})ᵀ S_in.
    o = o + jnp.dot(r_dec, s_ref[...], preferred_element_type=jnp.float32)

    # State update: S_out = e^{lc_C} ⊙ S_in + Σ_s (k_s e^{lc_C - lc_s}) v_sᵀ.
    lc_last = lc[-1]                                                  # (K,)
    k_carry = k * jnp.exp(lc_last[None, :] - lc)                      # (C, K)
    s_ref[...] = (jnp.exp(lc_last)[:, None] * s_ref[...]
                  + jnp.dot(k_carry.T, v, preferred_element_type=jnp.float32))

    o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                w: jnp.ndarray, u: jnp.ndarray, chunk: int = 64,
                interpret: bool = False) -> jnp.ndarray:
    """Batched WKV6.  r,k,w: (BH, T, K), v: (BH, T, V), u: (K,) → (BH, T, V).

    T % chunk == 0 required (ops.py pads).  float32 accumulation throughout;
    per-chunk log-space telescoping keeps the decay products stable for the
    chunk sizes used on TPU (64/128).
    """
    BH, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    u2 = u.reshape(1, K)

    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, V), v.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u2)
