"""Pallas TPU kernels for the paper's compute hot spots.

* ``matmul``       — shared VMEM-tiled matmul engine
* ``mds_encode``   — Ã = G·A master-side encoding (systematic fast path)
* ``coded_matvec`` — per-worker Ã_n·x products
* ``wkv6``         — chunk-parallel RWKV-6 recurrence (TPU adaptation)

Each kernel has a pure-jnp oracle in ``ref.py``; tests sweep shapes/dtypes in
interpret mode and assert allclose.
"""
from . import ref  # noqa: F401
from .ops import coded_matvec, matmul, mds_encode, wkv6  # noqa: F401
