"""MDS encoding kernels: Ã = G @ A, plus counter-generated parity.

The generator is (L̃, L) with L̃ ≈ 2L under Theorem-1 loads, so encoding is
a skinny-times-wide matmul over the task matrix.  Systematic generators make
the top L rows an identity — the wrapper in ops.py skips them and only runs
the kernel over the parity rows, which halves encode FLOPs for the default
redundancy (a beyond-paper optimization recorded in EXPERIMENTS.md §Perf).

Virtual parity ("generated" mode) goes one step further: parity rows are a
pure function of ``(layer key, packed row counter)`` through the shared
threefry derivation in :mod:`repro.core.mds`, so the kernels here *derive*
each parity tile inside the grid instead of reading a materialised ``R`` or
``WR`` from HBM:

* :func:`counter_parity_rows_pallas` — the standalone generator (encode /
  verify paths): R rows, bit-identical to the host
  :func:`repro.core.mds.counter_parity_rows` twin.
* :func:`gen_parity_matvec_pallas` — the fused serving kernel:
  ``y = R_gen @ (W @ x)`` accumulated tile-by-tile against the
  device-resident W, so the encoded parity block ``WR`` is never stored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import mds
from .matmul import DEFAULT_BLOCK, matmul_pallas

__all__ = ["mds_encode_pallas", "counter_parity_rows_pallas",
           "gen_parity_matvec_pallas"]


def mds_encode_pallas(g: jnp.ndarray, a: jnp.ndarray,
                      block=DEFAULT_BLOCK, interpret: bool = False) -> jnp.ndarray:
    """Ã = G @ A with VMEM-tiled accumulation (see matmul.py)."""
    return matmul_pallas(g, a, block=block, interpret=interpret)


def _parity_tile(key_ref, scale_ref, ctr_ref, j, block_cols: int):
    """One (block_rows, block_cols) tile of counter-derived parity values.

    Shared by both generated-parity kernels: the arithmetic is the
    numpy/jnp-generic :func:`repro.core.mds.counter_gaussian_tile`, so the
    tile is bit-identical to the host derivation for the same counters.
    """
    cols = jax.lax.broadcasted_iota(jnp.uint32, (1, block_cols), 1) \
        + (j * block_cols).astype(jnp.uint32)
    return mds.counter_gaussian_tile(key_ref[0, 0], key_ref[0, 1],
                                     ctr_ref[...], cols, scale_ref[0, 0])


def _rows_kernel(key_ref, scale_ref, ctr_ref, o_ref, *, block_cols: int):
    o_ref[...] = _parity_tile(key_ref, scale_ref, ctr_ref,
                              pl.program_id(1), block_cols)


@functools.partial(jax.jit,
                   static_argnames=("n_cols", "block_rows", "block_cols",
                                    "interpret"))
def counter_parity_rows_pallas(key: jnp.ndarray, scale: jnp.ndarray,
                               ctrs: jnp.ndarray, *, n_cols: int,
                               block_rows: int = 128, block_cols: int = 128,
                               interpret: bool = False) -> jnp.ndarray:
    """Counter-derived parity rows R[ctrs] — the in-kernel generator.

    ``key`` (1, 2) uint32 layer key, ``scale`` (1, 1) float32
    ``sqrt(3/L)``, ``ctrs`` (Rp, 1) packed row counters
    (:func:`repro.core.mds.parity_counters`); Rp and ``n_cols`` must be
    block multiples (ops.py pads and slices).  Output (Rp, n_cols)
    float32 — bit-identical to the host twin for the same counters.
    """
    Rp = ctrs.shape[0]
    assert Rp % block_rows == 0 and n_cols % block_cols == 0
    return pl.pallas_call(
        functools.partial(_rows_kernel, block_cols=block_cols),
        grid=(Rp // block_rows, n_cols // block_cols),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols),
                               lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, n_cols), jnp.float32),
        interpret=interpret,
    )(key, scale, ctrs)


def _gen_matvec_kernel(key_ref, scale_ref, ctr_ref, w_ref, x_ref, o_ref,
                       acc_ref, *, k_steps: int, block_k: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    r_blk = _parity_tile(key_ref, scale_ref, ctr_ref,
                         pl.program_id(1), block_k)
    # contract the generated tile against the resident W tile: the encoded
    # parity row (R @ W) is never formed — only its product with x
    wx = jnp.dot(w_ref[...], x_ref[...],
                 preferred_element_type=jnp.float32)          # (bk, C)
    acc_ref[...] += jnp.dot(r_blk, wx,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_k", "interpret"))
def gen_parity_matvec_pallas(key: jnp.ndarray, scale: jnp.ndarray,
                             ctrs: jnp.ndarray, w: jnp.ndarray,
                             x: jnp.ndarray, *,
                             block_rows: int = 128, block_k: int = 128,
                             interpret: bool = False) -> jnp.ndarray:
    """Generated-parity products y = R_gen @ (W @ x), WR never stored.

    ``ctrs`` (Rp, 1) packed parity-row counters, ``w`` (Lp, D) the
    device-resident systematic weights (zero rows pad L→Lp — generated
    values beyond L contract against them to exactly zero), ``x`` (D, C).
    Grid (Rp/block_rows, Lp/block_k): each step derives one R tile from
    the counters, multiplies the matching W tile into x, and accumulates
    — the per-tile memory high-water is one (block_rows, block_k) R tile
    in registers/VMEM instead of a resident (n_parity, D) ``WR`` mirror.
    """
    Rp = ctrs.shape[0]
    Lp, D = w.shape
    assert Rp % block_rows == 0 and Lp % block_k == 0
    k_steps = Lp // block_k
    C = x.shape[1]
    return pl.pallas_call(
        functools.partial(_gen_matvec_kernel, k_steps=k_steps,
                          block_k=block_k),
        grid=(Rp // block_rows, k_steps),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (0, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((block_k, D), lambda i, k: (k, 0)),
            pl.BlockSpec((D, C), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_rows, C), jnp.float32)],
        interpret=interpret,
    )(key, scale, ctrs, w, x)
