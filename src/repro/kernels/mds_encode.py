"""MDS encoding kernel: Ã = G @ A (paper §II, the master-side hot spot).

The generator is (L̃, L) with L̃ ≈ 2L under Theorem-1 loads, so encoding is
a skinny-times-wide matmul over the task matrix.  Systematic generators make
the top L rows an identity — the wrapper in ops.py skips them and only runs
the kernel over the parity rows, which halves encode FLOPs for the default
redundancy (a beyond-paper optimization recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax.numpy as jnp

from .matmul import DEFAULT_BLOCK, matmul_pallas

__all__ = ["mds_encode_pallas"]


def mds_encode_pallas(g: jnp.ndarray, a: jnp.ndarray,
                      block=DEFAULT_BLOCK, interpret: bool = False) -> jnp.ndarray:
    """Ã = G @ A with VMEM-tiled accumulation (see matmul.py)."""
    return matmul_pallas(g, a, block=block, interpret=interpret)
