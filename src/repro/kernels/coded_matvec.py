"""Per-worker coded product kernel: y = Ã_n @ X (paper §II worker compute).

Each worker holds its slice Ã_n (l_n, S) resident and multiplies incoming
model vectors X (S, B) (B = 1 for matrix-vector, B > 1 for the iterated /
batched tasks of the paper's Remark 2).  The kernel keeps the X tile in VMEM
across the whole row-block sweep and accumulates in float32.

Grid is (rows, k) with k innermost — each output row-block's reduction
finishes before moving on, so only one (bm, B) accumulator tile lives in
VMEM at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["coded_matvec_pallas"]


def _matvec_kernel(a_ref, x_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_k", "interpret"))
def coded_matvec_pallas(a_tilde: jnp.ndarray, x: jnp.ndarray,
                        block_rows: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """y = Ã @ X;  Ã (L, S), X (S, B) → y (L, B).

    L % block_rows == 0 and S % block_k == 0 required (ops.py pads); B is
    kept whole in VMEM (pad to a lane multiple for real-TPU efficiency).
    """
    (L, S), (S2, B) = a_tilde.shape, x.shape
    assert S == S2, (a_tilde.shape, x.shape)
    assert L % block_rows == 0 and S % block_k == 0
    k_steps = S // block_k

    return pl.pallas_call(
        functools.partial(_matvec_kernel, k_steps=k_steps),
        grid=(L // block_rows, k_steps),
        in_specs=[
            pl.BlockSpec((block_rows, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_k, B), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, B), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, B), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows, B), jnp.float32)],
        interpret=interpret,
    )(a_tilde, x)
