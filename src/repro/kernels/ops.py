"""Public jit'd wrappers around the Pallas kernels.

These handle shape padding to MXU-aligned blocks, (S,) vs (S,B) vector
conventions, systematic-generator fast paths, and the interpret switch
(interpret=True on CPU so the kernels run everywhere; real lowering on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from ..obs import device_span
from .coded_matvec import coded_matvec_pallas
from .matmul import matmul_pallas
from .mds_encode import mds_encode_pallas
from .wkv6 import wkv6_pallas

__all__ = ["matmul", "mds_encode", "mds_encode_batch", "coded_matvec",
           "coded_matvec_batch", "coded_shard_matmul_batch", "wkv6",
           "default_interpret"]


def default_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, block=(128, 128, 128),
           interpret: bool | None = None) -> jnp.ndarray:
    """C = A @ B, padding both operands up to the block grid."""
    interpret = default_interpret() if interpret is None else interpret
    M, K = a.shape
    N = b.shape[1]
    bm, bn, bk = block
    ap = _pad_to(_pad_to(a, 0, bm), 1, bk)
    bp = _pad_to(_pad_to(b, 0, bk), 1, bn)
    out = matmul_pallas(ap, bp, block=block, interpret=interpret)
    return out[:M, :N]


def mds_encode(g: jnp.ndarray, a: jnp.ndarray, *, systematic: bool = True,
               block=(128, 128, 128),
               interpret: bool | None = None) -> jnp.ndarray:
    """Ã = G @ A.  With ``systematic`` the identity prefix is copied through
    and only the parity rows hit the MXU (halves encode FLOPs at the default
    2× redundancy)."""
    interpret = default_interpret() if interpret is None else interpret
    L = g.shape[1]
    if systematic and g.shape[0] > L:
        parity = matmul(g[L:], a, block=block, interpret=interpret)
        return jnp.concatenate([a.astype(parity.dtype), parity], axis=0)
    return matmul(g, a, block=block, interpret=interpret)


def mds_encode_batch(g: jnp.ndarray, a: jnp.ndarray, *,
                     systematic: bool = True, block=(128, 128, 128),
                     interpret: bool | None = None) -> jnp.ndarray:
    """Batched Ã_b = G_b @ A_b over a leading task/master axis.

    ``g`` is (B, L̃, L) per-task generators or a shared (L̃, L); ``a`` is
    (B, L, S).  ``vmap`` of the Pallas call adds a grid dimension, so the
    whole stack is one kernel launch."""
    interpret = default_interpret() if interpret is None else interpret
    enc = functools.partial(mds_encode, systematic=systematic, block=block,
                            interpret=interpret)
    if g.ndim == 2:
        return jax.vmap(lambda ab: enc(g, ab))(a)
    return jax.vmap(enc)(g, a)


def coded_matvec_batch(a_tilde: jnp.ndarray, x: jnp.ndarray, *,
                       block_rows: int = 128, block_k: int = 128,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Batched per-task coded products y_b = Ã_b @ x_b.

    ``a_tilde`` (B, L, S), ``x`` (B, S) or (B, S, C) → (B, L[, C])."""
    interpret = default_interpret() if interpret is None else interpret
    mv = functools.partial(coded_matvec, block_rows=block_rows,
                           block_k=block_k, interpret=interpret)
    return jax.vmap(mv)(a_tilde, x)


def coded_shard_matmul_batch(tiles: jnp.ndarray, x: jnp.ndarray, *,
                             block_rows: int = 128, block_k: int = 128,
                             mode: str = "pallas",
                             interpret: bool | None = None) -> jnp.ndarray:
    """Every packed shard tile of a serving step against one operand, in
    one pass: ``tiles`` (T, R, K) 128-aligned encoded-row tiles (the
    ragged per-worker shard slices of a whole step barrier, bucketed and
    zero-padded by ``repro.serve_coded.packing``), ``x`` (K, C) the shared
    right-hand activations → (T, R, C).

    ``mode="pallas"`` flattens the tile axis into the row grid of the
    ``coded_matvec`` kernel — because R and K are already block-aligned,
    the whole stack is exactly one kernel launch with a (T·R/block_rows,
    K/block_k) grid (the same block layout ``coded_matvec_batch`` uses,
    without the vmap-added grid dimension).  ``mode="vmap"`` is the plain
    jnp fallback for the jax backend.  Per-row results are independent of
    the tile bucketing (each output row is one dot), which is what lets
    the packing layer re-bucket ragged shards freely.
    """
    interpret = default_interpret() if interpret is None else interpret
    T, R, K = tiles.shape
    if mode not in ("vmap", "pallas"):
        raise ValueError(f"unknown mode {mode!r}; expected pallas | vmap")
    if mode == "pallas" and (R % block_rows or K % block_k):
        raise ValueError(f"tiles must be block-aligned, got R={R} K={K} "
                         f"for block ({block_rows}, {block_k})")
    # the exit fence (block_until_ready) only engages while a tracer is
    # recording; the untraced path keeps jax's async dispatch
    with device_span("coded_shard_matmul_batch", cat="kernel",
                     args={"tiles": T, "rows": T * R, "k": K,
                           "mode": mode}) as fence:
        if mode == "vmap":
            return fence(jax.vmap(lambda t: t @ x)(tiles))
        flat = coded_matvec_pallas(tiles.reshape(T * R, K), x,
                                   block_rows=block_rows, block_k=block_k,
                                   interpret=interpret)
        return fence(flat.reshape(T, R, -1))


def coded_matvec(a_tilde: jnp.ndarray, x: jnp.ndarray, *,
                 block_rows: int = 128, block_k: int = 128,
                 interpret: bool | None = None) -> jnp.ndarray:
    """y = Ã @ x for x (S,) or (S, B); pads rows/contraction, keeps B whole."""
    interpret = default_interpret() if interpret is None else interpret
    squeeze = x.ndim == 1
    xm = x[:, None] if squeeze else x
    L, S = a_tilde.shape
    ap = _pad_to(_pad_to(a_tilde, 0, block_rows), 1, block_k)
    xp = _pad_to(xm, 0, block_k)
    y = coded_matvec_pallas(ap, xp, block_rows=block_rows, block_k=block_k,
                            interpret=interpret)[:L]
    return y[:, 0] if squeeze else y


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray, *, chunk: int = 64,
         interpret: bool | None = None) -> jnp.ndarray:
    """Batched chunk-parallel WKV6.  r,k,w (BH,T,K), v (BH,T,V), u (K,)."""
    interpret = default_interpret() if interpret is None else interpret
    BH, T, K = r.shape
    if T % chunk:
        pad = chunk - T % chunk
        r = _pad_to(r, 1, chunk)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    out = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :T]
