"""Public jit'd wrappers around the Pallas kernels.

These handle shape padding to MXU-aligned blocks, (S,) vs (S,B) vector
conventions, systematic-generator fast paths, and the interpret switch
(interpret=True on CPU so the kernels run everywhere; real lowering on TPU).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from ..core import mds
from ..obs import device_span
from .coded_matvec import coded_matvec_pallas
from .matmul import matmul_pallas
from .mds_encode import (counter_parity_rows_pallas, gen_parity_matvec_pallas,
                         mds_encode_pallas)
from .wkv6 import wkv6_pallas

__all__ = ["matmul", "mds_encode", "mds_encode_batch", "coded_matvec",
           "coded_matvec_batch", "coded_shard_matmul_batch",
           "counter_parity_rows", "gen_parity_products", "GeneratedParity",
           "wkv6", "default_interpret"]


def default_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, block=(128, 128, 128),
           interpret: bool | None = None) -> jnp.ndarray:
    """C = A @ B, padding both operands up to the block grid."""
    interpret = default_interpret() if interpret is None else interpret
    M, K = a.shape
    N = b.shape[1]
    bm, bn, bk = block
    ap = _pad_to(_pad_to(a, 0, bm), 1, bk)
    bp = _pad_to(_pad_to(b, 0, bk), 1, bn)
    out = matmul_pallas(ap, bp, block=block, interpret=interpret)
    return out[:M, :N]


def mds_encode(g: jnp.ndarray, a: jnp.ndarray, *, systematic: bool = True,
               block=(128, 128, 128),
               interpret: bool | None = None) -> jnp.ndarray:
    """Ã = G @ A.  With ``systematic`` the identity prefix is copied through
    and only the parity rows hit the MXU (halves encode FLOPs at the default
    2× redundancy)."""
    interpret = default_interpret() if interpret is None else interpret
    L = g.shape[1]
    if systematic and g.shape[0] > L:
        parity = matmul(g[L:], a, block=block, interpret=interpret)
        return jnp.concatenate([a.astype(parity.dtype), parity], axis=0)
    return matmul(g, a, block=block, interpret=interpret)


def mds_encode_batch(g: jnp.ndarray, a: jnp.ndarray, *,
                     systematic: bool = True, block=(128, 128, 128),
                     interpret: bool | None = None) -> jnp.ndarray:
    """Batched Ã_b = G_b @ A_b over a leading task/master axis.

    ``g`` is (B, L̃, L) per-task generators or a shared (L̃, L); ``a`` is
    (B, L, S).  ``vmap`` of the Pallas call adds a grid dimension, so the
    whole stack is one kernel launch."""
    interpret = default_interpret() if interpret is None else interpret
    enc = functools.partial(mds_encode, systematic=systematic, block=block,
                            interpret=interpret)
    if g.ndim == 2:
        return jax.vmap(lambda ab: enc(g, ab))(a)
    return jax.vmap(enc)(g, a)


def coded_matvec_batch(a_tilde: jnp.ndarray, x: jnp.ndarray, *,
                       block_rows: int = 128, block_k: int = 128,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Batched per-task coded products y_b = Ã_b @ x_b.

    ``a_tilde`` (B, L, S), ``x`` (B, S) or (B, S, C) → (B, L[, C])."""
    interpret = default_interpret() if interpret is None else interpret
    mv = functools.partial(coded_matvec, block_rows=block_rows,
                           block_k=block_k, interpret=interpret)
    return jax.vmap(mv)(a_tilde, x)


def _parity_key_arrays(key: Tuple[int, int],
                       L: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Layer key / scale as the (1, 2) uint32 + (1, 1) f32 kernel operands
    (array operands, so layers re-use one compiled kernel)."""
    key_arr = jnp.asarray(np.asarray(key, dtype=np.uint32)[None, :])
    scale = jnp.full((1, 1), np.float32(np.sqrt(3.0 / L)), jnp.float32)
    return key_arr, scale


def counter_parity_rows(key: Tuple[int, int], L: int, ctrs, *,
                        block_rows: int = 128, block_cols: int = 128,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Counter-derived parity generator rows R[ctrs] (n, L) float32.

    The standalone in-kernel generator for encode/verify paths — pads the
    row counters up to the block grid and slices back; bit-identical to
    :func:`repro.core.mds.counter_parity_rows` for the same ``(key,
    ctrs)`` (the shared threefry tile arithmetic guarantees it).
    """
    interpret = default_interpret() if interpret is None else interpret
    ctrs = jnp.asarray(np.asarray(ctrs, dtype=np.uint32))[:, None]
    n = ctrs.shape[0]
    key_arr, scale = _parity_key_arrays(key, L)
    ctrs_p = _pad_to(ctrs, 0, block_rows)
    cols = -(-L // block_cols) * block_cols
    out = counter_parity_rows_pallas(key_arr, scale, ctrs_p, n_cols=cols,
                                     block_rows=block_rows,
                                     block_cols=block_cols,
                                     interpret=interpret)
    return out[:n, :L]


@functools.lru_cache(maxsize=None)
def _derive_rows_xla(L: int):
    """Jitted XLA twin of the parity-row derivation for off-TPU runs.

    Off-TPU the fused Pallas kernel only executes in interpret mode —
    Python-level emulation, orders of magnitude slower than the compiled
    materialised path it must keep pace with.  The counter tile
    arithmetic is backend-generic, so the same derivation runs as
    straight XLA ops (same threefry rounds, same fixed-order float32
    adds) — bit-identical rows by construction."""
    def f(key_arr, scale, ctrs):
        cols = jax.lax.broadcasted_iota(jnp.uint32, (1, L), 1)
        return mds.counter_gaussian_tile(key_arr[0, 0], key_arr[0, 1],
                                         ctrs, cols, scale)
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _gen_contract():
    return jax.jit(lambda r, w, x: r @ (w @ x))


#: steady-state serving replays one frozen counter schedule per plan
#: entry, so the derived R_gen coefficient rows (n, L) — NOT the encoded
#: WR mirror — are memoised on device across steps.  Bounded LRU; only
#: the off-TPU XLA path uses it (on TPU the fused kernel regenerates
#: in-VMEM for free).
GEN_ROWS_MEMO = 8
_gen_rows_memo: "dict[tuple, jnp.ndarray]" = {}


def _gen_rows_device(key: Tuple[int, int], ctrs: np.ndarray,
                     L: int) -> jnp.ndarray:
    mk = (int(key[0]), int(key[1]), int(L),
          np.asarray(ctrs, np.uint32).tobytes())
    r = _gen_rows_memo.pop(mk, None)
    if r is None:
        key_arr, scale = _parity_key_arrays(key, L)
        cj = jnp.asarray(np.asarray(ctrs, dtype=np.uint32))[:, None]
        r = _derive_rows_xla(L)(key_arr, scale, cj)
    _gen_rows_memo[mk] = r                     # re-insert: LRU order
    while len(_gen_rows_memo) > GEN_ROWS_MEMO:
        _gen_rows_memo.pop(next(iter(_gen_rows_memo)))
    return r


@functools.lru_cache(maxsize=None)
def _gen_vmap_step(n_specs: int):
    """One compiled step for vmap-mode generated parity: base tile
    matmul + every spec's ``R_gen @ (W @ x)`` + lane scatter, fused so
    the virtual path costs one dispatch like the materialised one."""
    def f(tiles, x, lanes, rs, ws):
        T, R, _ = tiles.shape
        flat = jax.vmap(lambda t: t @ x)(tiles).reshape(T * R, -1)
        for i in range(n_specs):
            flat = flat.at[lanes[i]].set(
                (rs[i] @ (ws[i] @ x)).astype(flat.dtype))
        return flat.reshape(T, R, -1)
    return jax.jit(f)


def gen_parity_products(key: Tuple[int, int], ctrs, w: jnp.ndarray,
                        x: jnp.ndarray, *,
                        block_rows: int = 128, block_k: int = 128,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Generated-parity shard products (n, C): ``R_gen[ctrs] @ (W @ x)``.

    ``w`` (L, D) float32 systematic weights (device-resident), ``x``
    (D, C).  The fused kernel derives each parity tile from the packed
    row counters and contracts it against W tile-by-tile — the virtual
    parity path's device execution, with no ``WR`` mirror in HBM.
    """
    interpret = default_interpret() if interpret is None else interpret
    ctrs_host = np.asarray(ctrs, dtype=np.uint32)
    ctrs = jnp.asarray(ctrs_host)[:, None]
    n = ctrs.shape[0]
    L, D = w.shape
    key_arr, scale = _parity_key_arrays(key, L)
    with device_span("gen_parity_products", cat="kernel",
                     args={"rows": int(n), "L": int(L)}) as fence:
        if interpret:
            r = _gen_rows_device(key, ctrs_host, L)
            out = fence(_gen_contract()(r, w, x))
        else:
            ctrs_p = _pad_to(ctrs, 0, block_rows)
            wp = _pad_to(_pad_to(w, 0, block_k), 1, 128)
            xp = _pad_to(x, 0, 128)[:wp.shape[1]]
            out = fence(gen_parity_matvec_pallas(
                key_arr, scale, ctrs_p, wp, xp, block_rows=block_rows,
                block_k=block_k, interpret=False))
    return out[:n]


@dataclasses.dataclass
class GeneratedParity:
    """Virtual-parity lane spec for one packed problem.

    ``lanes`` index into the flattened (T·R,) tile row space; their
    products come from the generated kernel instead of the materialised
    tiles (whose corresponding rows are zero-filled).  ``ctrs`` are the
    packed (row | draw << 24) counters — the per-row seed schedule frozen
    into the plan — and ``w`` the layer's device-resident systematic
    weights.
    """
    lanes: np.ndarray           # (n,) flat lane indices in tile space
    ctrs: np.ndarray            # (n,) packed parity-row counters (uint32)
    key: Tuple[int, int]        # per-layer threefry key
    w: jnp.ndarray              # (L, D) float32 systematic weights


def coded_shard_matmul_batch(tiles: jnp.ndarray, x: jnp.ndarray, *,
                             block_rows: int = 128, block_k: int = 128,
                             mode: str = "pallas",
                             parity_mode: str = "materialized",
                             parity: Optional[Sequence[GeneratedParity]]
                             = None,
                             interpret: bool | None = None) -> jnp.ndarray:
    """Every packed shard tile of a serving step against one operand, in
    one pass: ``tiles`` (T, R, K) 128-aligned encoded-row tiles (the
    ragged per-worker shard slices of a whole step barrier, bucketed and
    zero-padded by ``repro.serve_coded.packing``), ``x`` (K, C) the shared
    right-hand activations → (T, R, C).

    ``mode="pallas"`` flattens the tile axis into the row grid of the
    ``coded_matvec`` kernel — because R and K are already block-aligned,
    the whole stack is exactly one kernel launch with a (T·R/block_rows,
    K/block_k) grid (the same block layout ``coded_matvec_batch`` uses,
    without the vmap-added grid dimension).  ``mode="vmap"`` is the plain
    jnp fallback for the jax backend.  Per-row results are independent of
    the tile bucketing (each output row is one dot), which is what lets
    the packing layer re-bucket ragged shards freely.

    ``parity_mode="generated"`` is the virtual-parity execution: parity
    lanes are zero rows in ``tiles`` and each :class:`GeneratedParity`
    entry of ``parity`` re-derives those lanes' products through the
    fused :func:`gen_parity_products` kernel (threefry counters against
    the layer's device-resident W) — the encoded parity rows never exist
    in HBM.  ``"materialized"`` (default) reads every lane from the
    tiles, exactly the historical behaviour.
    """
    interpret = default_interpret() if interpret is None else interpret
    T, R, K = tiles.shape
    if mode not in ("vmap", "pallas"):
        raise ValueError(f"unknown mode {mode!r}; expected pallas | vmap")
    if parity_mode not in ("materialized", "generated"):
        raise ValueError(f"unknown parity_mode {parity_mode!r}; expected "
                         f"materialized | generated")
    if mode == "pallas" and (R % block_rows or K % block_k):
        raise ValueError(f"tiles must be block-aligned, got R={R} K={K} "
                         f"for block ({block_rows}, {block_k})")
    gen = parity_mode == "generated" and parity
    # the exit fence (block_until_ready) only engages while a tracer is
    # recording; the untraced path keeps jax's async dispatch
    with device_span("coded_shard_matmul_batch", cat="kernel",
                     args={"tiles": T, "rows": T * R, "k": K, "mode": mode,
                           "parity_mode": parity_mode}) as fence:
        if gen and mode == "vmap" and interpret:
            # one compiled dispatch: base matmul + generated lanes, with
            # the derived R_gen rows memoised across steps of the plan
            specs = list(parity)
            lanes = tuple(jnp.asarray(np.asarray(s.lanes, dtype=np.int64))
                          for s in specs)
            rs = tuple(_gen_rows_device(s.key, s.ctrs, s.w.shape[0])
                       for s in specs)
            ws = tuple(s.w for s in specs)
            return fence(_gen_vmap_step(len(specs))(tiles, x, lanes,
                                                    rs, ws))
        if mode == "vmap":
            out = fence(jax.vmap(lambda t: t @ x)(tiles))
        else:
            flat = coded_matvec_pallas(tiles.reshape(T * R, K), x,
                                       block_rows=block_rows,
                                       block_k=block_k, interpret=interpret)
            out = fence(flat.reshape(T, R, -1))
    if not gen:
        return out
    flat = out.reshape(T * R, -1)
    for spec in parity:
        yp = gen_parity_products(spec.key, spec.ctrs, spec.w, x,
                                 block_rows=block_rows, block_k=block_k,
                                 interpret=interpret)
        flat = flat.at[jnp.asarray(np.asarray(spec.lanes,
                                              dtype=np.int64))].set(
            yp.astype(flat.dtype))
    return flat.reshape(T, R, -1)


def coded_matvec(a_tilde: jnp.ndarray, x: jnp.ndarray, *,
                 block_rows: int = 128, block_k: int = 128,
                 interpret: bool | None = None) -> jnp.ndarray:
    """y = Ã @ x for x (S,) or (S, B); pads rows/contraction, keeps B whole."""
    interpret = default_interpret() if interpret is None else interpret
    squeeze = x.ndim == 1
    xm = x[:, None] if squeeze else x
    L, S = a_tilde.shape
    ap = _pad_to(_pad_to(a_tilde, 0, block_rows), 1, block_k)
    xp = _pad_to(xm, 0, block_k)
    y = coded_matvec_pallas(ap, xp, block_rows=block_rows, block_k=block_k,
                            interpret=interpret)[:L]
    return y[:, 0] if squeeze else y


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray, *, chunk: int = 64,
         interpret: bool | None = None) -> jnp.ndarray:
    """Batched chunk-parallel WKV6.  r,k,w (BH,T,K), v (BH,T,V), u (K,)."""
    interpret = default_interpret() if interpret is None else interpret
    BH, T, K = r.shape
    if T % chunk:
        pad = chunk - T % chunk
        r = _pad_to(r, 1, chunk)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    out = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :T]
