"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (interpret=True on
CPU, real lowering on TPU).  Keep them boring.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["matmul_ref", "mds_encode_ref", "coded_matvec_ref", "wkv6_chunk_ref"]


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B with float32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def mds_encode_ref(g: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Ã = G @ A — row-wise MDS encoding (paper §II)."""
    return jnp.dot(g, a, preferred_element_type=jnp.float32).astype(a.dtype)


def coded_matvec_ref(a_tilde: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y = Ã @ x for x of shape (S,) or (S, B)."""
    squeeze = x.ndim == 1
    xm = x[:, None] if squeeze else x
    y = jnp.dot(a_tilde, xm, preferred_element_type=jnp.float32).astype(x.dtype)
    return y[:, 0] if squeeze else y


def wkv6_chunk_ref(r, k, v, w, u):
    """Chunked RWKV-6 WKV oracle (sequential over time, O(T) state).

    r,k,w: (T, K)  v: (T, V)  u: (K,)   state: (K, V)
    out_t = (diag(r_t) @ (S + u ⊗ k_t ⊙ v_t-outer)) summed over K:
        o_t = rᵀ_t (S_t + (u ⊙ k_t) v_tᵀ),   S_{t+1} = diag(w_t) S_t + k_t v_tᵀ
    """
    import jax

    T, K = k.shape
    V = v.shape[1]
    S0 = jnp.zeros((K, V), dtype=jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[:, None] * v_t[None, :]
        o = ((S + u[:, None] * kv) * r_t[:, None]).sum(axis=0)
        S_new = w_t[:, None] * S + kv
        return S_new, o

    _, o = jax.lax.scan(step, S0, (r.astype(jnp.float32), k.astype(jnp.float32),
                                   v.astype(jnp.float32), w.astype(jnp.float32)))
    return o.astype(v.dtype)
