"""Tiled matmul Pallas kernel — the shared engine for MDS encoding (G @ A)
and the per-worker coded products (Ã_n @ X).

TPU adaptation (DESIGN.md §2): blocks are MXU-aligned (multiples of 128 on
the contracting/lane dims), partial products accumulate in a float32 VMEM
scratch across the k-grid, and the output is written once on the final k
step.  Grid order is (i, j, k) with k innermost, so each output tile stays
resident in VMEM for its whole reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_pallas", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray,
                  block: tuple[int, int, int] = DEFAULT_BLOCK,
                  interpret: bool = False) -> jnp.ndarray:
    """C = A @ B via a VMEM-tiled Pallas kernel.

    A: (M, K), B: (K, N) → C: (M, N).  Shapes must be divisible by ``block``
    (the ops.py wrappers pad); accumulation is float32 regardless of input
    dtype.
    """
    (M, K), (K2, N) = a.shape, b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = block
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, block)
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
