"""Jamba-1.5 Large 398B [arXiv:2403.19887]: 72L, d_model 8192, 64H GQA kv=8,
d_ff 24576, vocab 65536; attention:mamba 1:7 interleave, MoE 16e top-2 every
other layer.  Block of 8 layers = [attn, m, m, m, m, m, m, m] with MoE on the
even positions, repeated 9×.  Mamba state ⇒ long_500k runs."""
from repro.models.config import ArchConfig, LayerSpec, MambaConfig, MoEConfig


def _block(window=None):
    layers = []
    for i in range(8):
        mixer = "attn" if i == 0 else "mamba"
        ffn = "moe" if i % 2 == 1 else "swiglu"
        layers.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(layers)


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=24576, vocab=65536,
        block=_block(), n_repeats=9,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        subquadratic=True,
    )


def smoke_config() -> ArchConfig:
    layers = (LayerSpec(mixer="attn", ffn="swiglu"),
              LayerSpec(mixer="mamba", ffn="moe"),
              LayerSpec(mixer="mamba", ffn="swiglu"))
    return ArchConfig(
        name="jamba-smoke", family="hybrid",
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512,
        block=layers, n_repeats=2,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        subquadratic=True,
        dtype="float32",
    )
