"""DeepSeek-V3 671B [arXiv:2412.19437]: 61L, d_model 7168, 128 MLA heads,
MoE 1 shared + 256 routed top-8 (d_expert 2048), first 3 layers dense
(d_ff 18432), MTP head, vocab 129280."""
from repro.models.config import ArchConfig, LayerSpec, MLAConfig, MoEConfig


def config() -> ArchConfig:
    dense = LayerSpec(mixer="attn", ffn="swiglu")
    moe = LayerSpec(mixer="attn", ffn="moe")
    return ArchConfig(
        name="deepseek-v3-671b", family="moe",
        d_model=7168, n_heads=128, n_kv_heads=128, d_head=128,
        d_ff=18432, vocab=129280,
        prefix=(dense, dense, dense),
        block=(moe,), n_repeats=58,
        mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
                      v_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, n_shared=1),
        mtp=True,
        rope_base=10_000.0,
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    dense = LayerSpec(mixer="attn", ffn="swiglu")
    moe = LayerSpec(mixer="attn", ffn="moe")
    return ArchConfig(
        name="deepseek-v3-smoke", family="moe",
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=512,
        prefix=(dense,),
        block=(moe,), n_repeats=2,
        mla=MLAConfig(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16,
                      v_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, n_shared=1),
        mtp=True,
        dtype="float32",
    )
