"""Gemma-3 12B [hf:google/gemma-3-12b-pt]: 48L, d_model 3840, 16H GQA kv=8
(d_head 256), d_ff 15360, vocab 262144, 5:1 local(window 1024):global
attention, dual RoPE bases (10k local / 1M global), 128k context.

The 5:1 sliding:global pattern keeps the effective KV state sub-quadratic
in practice, so this arch runs the long_500k cell (DESIGN.md §4)."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    local = LayerSpec(mixer="attn", ffn="swiglu", sliding_window=1024)
    glob = LayerSpec(mixer="attn", ffn="swiglu", sliding_window=None)
    return ArchConfig(
        name="gemma3-12b", family="dense",
        d_model=3840, n_heads=16, n_kv_heads=8, d_head=256,
        d_ff=15360, vocab=262144,
        block=(local, local, local, local, local, glob), n_repeats=8,
        rope_base=1_000_000.0, rope_base_local=10_000.0,
        tie_embeddings=True,
        subquadratic=True,
    )


def smoke_config() -> ArchConfig:
    local = LayerSpec(mixer="attn", ffn="swiglu", sliding_window=8)
    glob = LayerSpec(mixer="attn", ffn="swiglu", sliding_window=None)
    return ArchConfig(
        name="gemma3-smoke", family="dense",
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512,
        block=(local, local, glob), n_repeats=2,
        rope_base=1_000_000.0, rope_base_local=10_000.0,
        tie_embeddings=True,
        subquadratic=True,
        dtype="float32",
    )
