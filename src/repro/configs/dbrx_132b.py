"""DBRX 132B [hf:databricks/dbrx-base]: 40L, d_model 6144, 48H GQA kv=8,
MoE 16 experts top-4 (d_expert 10752), vocab 100352."""
from repro.models.config import ArchConfig, LayerSpec, MoEConfig


def config() -> ArchConfig:
    moe = LayerSpec(mixer="attn", ffn="moe")
    return ArchConfig(
        name="dbrx-132b", family="moe",
        d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=10752, vocab=100352,
        block=(moe,), n_repeats=40,
        moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752),
        rope_base=500_000.0,
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    moe = LayerSpec(mixer="attn", ffn="moe")
    return ArchConfig(
        name="dbrx-smoke", family="moe",
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=96, vocab=512,
        block=(moe,), n_repeats=2,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=96),
        dtype="float32",
    )
