"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Every assigned architecture is one module exposing ``config()`` (the exact
published shape) and ``smoke_config()`` (a reduced same-family variant for
CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import List

from ..models.config import ArchConfig

ARCH_IDS: List[str] = [
    "deepseek_v3_671b",
    "dbrx_132b",
    "seamless_m4t_large_v2",
    "nemotron_4_15b",
    "gemma3_12b",
    "glm4_9b",
    "llama3_2_1b",
    "jamba_1_5_large_398b",
    "internvl2_26b",
    "rwkv6_7b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "deepseek-v3-671b": "deepseek_v3_671b",
    "dbrx-132b": "dbrx_132b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "nemotron-4-15b": "nemotron_4_15b",
    "gemma3-12b": "gemma3_12b",
    "glm4-9b": "glm4_9b",
    "llama3.2-1b": "llama3_2_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-26b": "internvl2_26b",
    "rwkv6-7b": "rwkv6_7b",
})


def _module(name: str):
    key = _ALIASES.get(name, name)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str) -> ArchConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).smoke_config()
