"""GLM-4 9B [hf:THUDM/glm-4-9b]: 40L, d_model 4096, 32H GQA kv=2,
d_ff 13696, RoPE, vocab 151552."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="swiglu")
    return ArchConfig(
        name="glm4-9b", family="dense",
        d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
        d_ff=13696, vocab=151552,
        block=(layer,), n_repeats=40,
        rope_base=10_000.0,
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="swiglu")
    return ArchConfig(
        name="glm4-smoke", family="dense",
        d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab=512,
        block=(layer,), n_repeats=2,
        dtype="float32",
    )
