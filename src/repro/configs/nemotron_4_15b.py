"""Nemotron-4 15B [arXiv:2402.16819]: 32L, d_model 6144, 48H GQA kv=8,
d_ff 24576, squared-ReLU FFN, vocab 256000."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="relu2")
    return ArchConfig(
        name="nemotron-4-15b", family="dense",
        d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=24576, vocab=256000,
        block=(layer,), n_repeats=32,
        ffn_act="relu2",
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="relu2")
    return ArchConfig(
        name="nemotron-smoke", family="dense",
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512,
        block=(layer,), n_repeats=2,
        ffn_act="relu2",
        dtype="float32",
    )
