"""RWKV-6 "Finch" 7B [arXiv:2404.05892]: 32L, d_model 4096, attention-free
(64 heads × head_size 64 WKV), channel-mix d_ff 14336, vocab 65536.
Constant-size recurrent state ⇒ long_500k runs."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    layer = LayerSpec(mixer="rwkv", ffn="swiglu")  # ffn field unused: cmix
    return ArchConfig(
        name="rwkv6-7b", family="ssm",
        d_model=4096, n_heads=64, n_kv_heads=64, d_head=64,
        d_ff=14336, vocab=65536,
        block=(layer,), n_repeats=32,
        rwkv_head_size=64,
        subquadratic=True,
    )


def smoke_config() -> ArchConfig:
    layer = LayerSpec(mixer="rwkv", ffn="swiglu")
    return ArchConfig(
        name="rwkv6-smoke", family="ssm",
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=512,
        block=(layer,), n_repeats=2,
        rwkv_head_size=16,
        subquadratic=True,
        dtype="float32",
    )
