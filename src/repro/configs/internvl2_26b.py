"""InternVL2 26B [arXiv:2404.16821]: InternLM2-20B language backbone
(48L, d_model 6144, 48H GQA kv=8, d_ff 16384, vocab 92553) with an InternViT
vision frontend.  The frontend is a STUB per the brief: ``input_specs``
provides precomputed patch embeddings prepended to the token sequence."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="swiglu")
    return ArchConfig(
        name="internvl2-26b", family="vlm",
        d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=16384, vocab=92553,
        block=(layer,), n_repeats=48,
        frontend="vision", frontend_dim=3200, frontend_len=1024,
        rope_base=1_000_000.0,
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="swiglu")
    return ArchConfig(
        name="internvl2-smoke", family="vlm",
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512,
        block=(layer,), n_repeats=2,
        frontend="vision", frontend_dim=48, frontend_len=16,
        dtype="float32",
    )
