"""Llama-3.2 1B [hf:meta-llama/Llama-3.2-1B]: 16L, d_model 2048, 32H GQA
kv=8 (d_head 64), d_ff 8192, vocab 128256, tied embeddings."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="swiglu")
    return ArchConfig(
        name="llama3.2-1b", family="dense",
        d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
        d_ff=8192, vocab=128256,
        block=(layer,), n_repeats=16,
        rope_base=500_000.0,
        tie_embeddings=True,
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    layer = LayerSpec(mixer="attn", ffn="swiglu")
    return ArchConfig(
        name="llama3.2-smoke", family="dense",
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512,
        block=(layer,), n_repeats=2,
        tie_embeddings=True,
        dtype="float32",
    )
