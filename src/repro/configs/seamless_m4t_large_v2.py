"""SeamlessM4T-large v2 [arXiv:2308.11596]: enc-dec transformer backbone,
24L encoder + 24L decoder, d_model 1024, 16H, d_ff 8192, vocab 256206.
The speech frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (per the assignment brief)."""
from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    enc = LayerSpec(mixer="attn", ffn="gelu")
    dec = LayerSpec(mixer="attn", ffn="gelu")
    return ArchConfig(
        name="seamless-m4t-large-v2", family="audio",
        d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
        d_ff=8192, vocab=256206,
        block=(dec,), n_repeats=24,
        enc_dec=True, n_enc_repeats=24, enc_block=(enc,),
        frontend="audio", frontend_dim=256, frontend_len=1500,
        ffn_act="gelu",
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    enc = LayerSpec(mixer="attn", ffn="gelu")
    dec = LayerSpec(mixer="attn", ffn="gelu")
    return ArchConfig(
        name="seamless-smoke", family="audio",
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=512,
        block=(dec,), n_repeats=2,
        enc_dec=True, n_enc_repeats=2, enc_block=(enc,),
        frontend="audio", frontend_dim=32, frontend_len=24,
        ffn_act="gelu",
        dtype="float32",
    )
