"""Heterogeneous-fleet scenario planner + small-model serving demo.

1. Plans a 2-job ("masters") inference fleet over mixed pod groups with the
   paper's algorithms: dedicated vs fractional assignment of pod groups to
   jobs, Theorem-1 loads, Monte-Carlo completion estimates, elastic re-plan
   after a pod failure.
2. Serves a reduced gemma3 with batched prefill + decode to show the serving
   path end-to-end (5:1 sliding/global attention, ring KV caches).

    PYTHONPATH=src python examples/heterogeneous_serving.py
"""
import numpy as np

from repro.core import (fractional_greedy, iterated_greedy,
                        plan_from_assignment)
from repro.parallel.hetero import hetero_split, replan_on_failure
from repro.sim import simulate_plan
from repro.sim.cluster import tpu_pod_cluster


def plan_fleet():
    profile = tpu_pod_cluster(n_pods=12, degraded=(2, 7))
    sc = profile.scenario(M=2, L=5e4)
    print(f"fleet: {profile.N} pod groups (2 degraded), 2 jobs")

    k = iterated_greedy(sc, rng=0)
    dedi = plan_from_assignment(sc, k)
    frac = fractional_greedy(sc, init=k)
    for name, plan in (("dedicated", dedi), ("fractional", frac)):
        r = simulate_plan(sc, plan, trials=10_000, rng=1)
        print(f"  {name:<11} predicted {plan.t:8.1f}  MC mean "
              f"{r.overall_mean:8.1f}")

    split = hetero_split(profile, global_batch=4096)
    print(f"  Thm-1 batch split over groups: {split.tolist()}")
    survivors, resplit = replan_on_failure(profile, 4096, failed=[2])
    print(f"  after losing group 2 → re-split: {resplit.tolist()}")


def serve_demo():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import (decode_step, init_cache_shapes, init_model,
                              prefill)
    cfg = get_smoke_config("gemma3-12b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, P, G = 4, 24, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          init_cache_shapes(cfg, B, P + G))
    logits, caches = prefill(params, {"tokens": toks}, caches, cfg=cfg)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = [tok]
    for i in range(G - 1):
        logits, caches = decode_step(params, tok,
                                     jnp.full((B,), P + i, jnp.int32),
                                     caches, cfg=cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = np.concatenate([np.asarray(t) for t in outs], 1)
    assert not np.isnan(gen).any()
    print(f"served {B} requests × {gen.shape[1]} tokens "
          f"(sliding+global KV rings) ✓  sample: {gen[0][:8].tolist()}")


if __name__ == "__main__":
    plan_fleet()
    serve_demo()
