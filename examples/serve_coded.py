"""Coded serving bridge demo: the StreamingExecutor plan as the admission/
batching policy of a real continuous-batching inference server.

Every generated token batch's large matmuls run as MDS-coded shards
across a heterogeneous EC2-fitted worker pool, sized by the paper's
Theorem-1/3 load allocation and admitted through the shared-worker ledger;
decoded outputs are verified exact against the uncoded pipeline.
``--coding-scope`` picks how deep the coding reaches (the output head
only, +FFN projections, or the full trunk incl. attention q/k/v/o), and
``--steps-per-dispatch`` batches several decode tokens per admission.
The same seeded workload (two tenants, mixed tight/loose deadlines,
mid-run worker degradation + death) is served under all three admission
policies so the columns are directly comparable.

    PYTHONPATH=src python examples/serve_coded.py \
        [--arch llama3.2-1b] [--requests 16] [--prompt-len 16] \
        [--gen-len 8] [--masters 2] [--slots 2] [--rate 0.02] \
        [--policies fifo,edf,fair] [--coding-scope head|ffn|trunk] \
        [--steps-per-dispatch 1] [--backend numpy|jax|pallas] [--seed 0] \
        [--trace out.json]
"""
import argparse
import sys

from repro.serve_coded import (CodedServingBridge, print_policy_table,
                               serve_policy_sweep, synthetic_requests)
from repro.stream import WorkerEvent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--masters", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2,
                    help="continuous-batching slots per master")
    ap.add_argument("--rate", type=float, default=0.02,
                    help="per-master arrival rate (requests per sim-ms)")
    ap.add_argument("--policies", default="fifo,edf,fair")
    ap.add_argument("--coding-scope", default="head",
                    choices=("head", "ffn", "trunk"),
                    help="code the output head only, +FFN projections, or "
                         "the full trunk (attention q/k/v/o too)")
    ap.add_argument("--steps-per-dispatch", type=int, default=1,
                    help="decode tokens generated per coded admission")
    ap.add_argument("--execution", default="batched",
                    choices=("serial", "batched"),
                    help="shard-execution engine: packed per-stage passes "
                         "or the shard-by-shard serial reference")
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "jax", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record per-step spans and write a Chrome/Perfetto "
                         "trace of the whole sweep here")
    ap.add_argument("--churn", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="degrade worker 2 mid-run, kill+revive worker 5 "
                         "(--no-churn for a stable pool)")
    args = ap.parse_args(argv)

    policies = tuple(args.policies.split(","))
    churn = [WorkerEvent(400.0, 2, "degrade", 4.0),
             WorkerEvent(1500.0, 5, "leave"),
             WorkerEvent(6000.0, 5, "join"),
             WorkerEvent(8000.0, 2, "restore")] if args.churn else []

    print(f"[demo] {args.requests} requests x {args.gen_len} tokens, "
          f"{args.masters} tenants, {args.slots} slots/tenant, "
          f"scope={args.coding_scope}, "
          f"steps/dispatch={args.steps_per_dispatch}, "
          f"churn={'on' if churn else 'off'}")
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(meta={"entry": "examples/serve_coded",
                              "scope": args.coding_scope,
                              "backend": args.backend})
    bridge = CodedServingBridge(
        masters=args.masters, arch=args.arch, backend=args.backend,
        seed=args.seed, slots_per_master=args.slots,
        coding_scope=args.coding_scope,
        steps_per_dispatch=args.steps_per_dispatch,
        execution=args.execution, tracer=tracer)
    bridge._setup_model(args.prompt_len + args.gen_len + 8)
    reqs = synthetic_requests(
        args.requests, masters=args.masters,
        vocab=bridge._model["cfg"].vocab, prompt_len=args.prompt_len,
        gen_len=args.gen_len, rate=args.rate, seed=args.seed)
    reports = serve_policy_sweep(bridge, reqs, policies, churn=churn)
    print_policy_table(reports)
    print("(sojourn in sim-ms; every coded matmul was scheduled by a "
          "StreamingExecutor plan and decode-verified against the uncoded "
          "pipeline)")
    if tracer is not None:
        from repro.serve_coded import write_trace_summary
        write_trace_summary(tracer, args.trace)
    return 0


if __name__ == "__main__":
    sys.exit(main())
