"""Quickstart: the paper's full workflow in ~60 lines.

Builds the paper's large-scale scenario (4 masters, 50 heterogeneous
workers, γ = 2u), runs every proposed algorithm, Monte-Carlos the completion
delays, then executes one realization end-to-end through the MDS-coded
pipeline with a straggler injected — and verifies the decoded results
numerically.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (coded_uniform, fractional_greedy, iterated_greedy,
                        plan_from_assignment, sca_enhance_plan,
                        large_scale_scenario, uncoded_uniform, Scenario)
from repro.runtime import CodedExecutor
from repro.sim import simulate_plan


def main():
    sc = large_scale_scenario(0)
    print(f"scenario: M={sc.M} masters, N={sc.N} workers, L={sc.L[0]:.0f} "
          f"rows each, γ=2u")

    k_iter = iterated_greedy(sc, rng=0)
    plans = {
        "uncoded uniform  ": uncoded_uniform(sc),
        "coded uniform [5]": coded_uniform(sc),
        "dedicated (Alg 1)": plan_from_assignment(sc, k_iter),
        "fractional (Alg 4)": fractional_greedy(sc, init=k_iter),
    }
    plans["dedicated + SCA  "] = sca_enhance_plan(sc, plans["dedicated (Alg 1)"])

    print(f"\n{'policy':<20} {'MC mean delay':>14}")
    for name, plan in plans.items():
        r = simulate_plan(sc, plan, trials=20_000, rng=1)
        print(f"{name:<20} {r.overall_mean:>11.1f} ms")

    # --- one realization through the real coded pipeline ----------------
    plan = plans["dedicated + SCA  "]
    plan.l[:] = plan.l / sc.L[:, None] * 512          # test-size matrices
    sc_small = Scenario(a=sc.a, u=sc.u, gamma=sc.gamma,
                        L=np.full(sc.M, 512.0))
    rng = np.random.default_rng(0)
    A = [rng.normal(size=(512, 64)) for _ in range(sc.M)]
    x = [rng.normal(size=64) for _ in range(sc.M)]
    ex = CodedExecutor(sc_small, plan, rng=2)
    results, report = ex.run(A, x, dead_workers=(7,))
    print(f"\ncoded execution with worker 7 dead:")
    print(f"  completion {report.overall:.1f} ms, decode_ok="
          f"{bool(report.decode_ok.all())}, max |err| "
          f"{report.max_err.max():.2e}")
    for m in range(sc.M):
        assert np.allclose(results[m], A[m] @ x[m], rtol=1e-5)
    print("  all masters recovered A·x exactly from the straggler prefix ✓")


if __name__ == "__main__":
    main()
