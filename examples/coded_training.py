"""End-to-end driver: train a ~1M-param llama-family model for a few hundred
steps with the full operational stack — deterministic data pipeline, AdamW,
checkpoint every 100 steps, a mid-run simulated preemption + restart, and
MDS-coded gradient aggregation surviving dropped shards.

    PYTHONPATH=src python examples/coded_training.py
"""
import os
import shutil

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import TokenStream
from repro.runtime.coded_grads import coded_grad_aggregate, encode_grad_shards
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig

CKPT = "/tmp/repro_example_ckpt"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_smoke_config("llama3.2-1b")
    stream = TokenStream(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=3)
    loop_cfg = TrainLoopConfig(total_steps=300, log_every=50, ckpt_every=100,
                               ckpt_dir=CKPT, n_microbatches=2, lr_peak=3e-3)

    # ---- phase 1: train 150 steps, then "preempt" -----------------------
    loop = TrainLoop(cfg, loop_cfg, stream, rng_seed=0)
    losses = []
    while loop.step < 150:
        batch = {k: jnp.asarray(v) for k, v in stream.batch(loop.step).items()}
        loop.params, loop.opt_state, m = loop._train_step(
            loop.params, loop.opt_state, batch)
        loop.step += 1
        if loop.step % 50 == 0:
            losses.append(float(m["loss"]))
            print(f"[phase1] step {loop.step} loss {losses[-1]:.4f}")
        if loop.step % loop_cfg.ckpt_every == 0:
            loop.save()
    print("[phase1] simulating preemption (process state discarded)")

    # ---- phase 2: fresh object, restore, continue -----------------------
    loop2 = TrainLoop(cfg, loop_cfg, stream, rng_seed=999)  # wrong seed on purpose
    assert loop2.try_restore(), "restore failed"
    print(f"[phase2] restored at step {loop2.step} (from checkpoint)")
    assert loop2.step == 100                                # last ckpt
    hist = loop2.run(callback=lambda s, m: print(
        f"[phase2] step {s} loss {m['loss']:.4f}"))
    final_loss = hist[-1][1]["loss"]
    assert final_loss < losses[0], (final_loss, losses[0])
    print(f"[phase2] loss improved {losses[0]:.4f} → {final_loss:.4f} ✓")

    # ---- coded gradient aggregation under stragglers ---------------------
    print("[coded-grads] 4 DP groups → 6 coded shards, 2 dropped:")
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)}
             for _ in range(4)]
    coded, ctx = encode_grad_shards(grads, n_coded=6, rng=1)
    agg = coded_grad_aggregate(coded, ctx, arrived=[0, 2, 4, 5])
    truth = np.sum([np.asarray(g["w"]) for g in grads], axis=0)
    err = float(np.abs(np.asarray(agg["w"]) - truth).max())
    print(f"[coded-grads] reconstruction max err {err:.2e} ✓")


if __name__ == "__main__":
    main()
