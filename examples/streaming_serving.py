"""End-to-end streaming demo: heterogeneous EC2 + TPU-pod pool, 3 masters
with Poisson arrivals, mid-run worker degradation + death, and a p99-sojourn
comparison of the three planning policies (dedicated / fractional / uncoded).

This is the paper's setting made *online*: instead of one static batch, task
streams hit the shared pool continuously, the scheduler tracks per-worker
share budgets across concurrent in-flight tasks, and the planner re-solves
as the pool churns.

    PYTHONPATH=src python examples/streaming_serving.py
"""
import numpy as np

from repro.sim.cluster import ClusterProfile, ec2_cluster, tpu_pod_cluster
from repro.stream import (BackendConfig, PoissonProcess, ReplanPolicy,
                          StreamConfig, StreamingExecutor, WorkerEvent)


def mixed_pool() -> ClusterProfile:
    """8 EC2 instances (2 fast c5.large) + 4 TPU pod groups, one degraded."""
    ec2 = ec2_cluster(N=8, n_fast=2, rng=0, gamma_over_u=2.0)
    tpu = tpu_pod_cluster(n_pods=4, degraded=(1,))
    classes = ec2.classes + tpu.classes
    members = tuple(ec2.members) + tuple(m + len(ec2.classes)
                                         for m in tpu.members)
    return ClusterProfile(classes=classes, members=members,
                          master_class=ec2.master_class)


def main():
    profile = mixed_pool()
    sc = profile.scenario(M=3, L=512.0)
    print(f"pool: {profile.N} workers "
          f"({', '.join(c.name for c in profile.classes)}), 3 masters, "
          f"L={int(sc.L[0])} coded rows/task")

    # mid-run churn: worker 3 slows 4x at t=1.5s, worker 7 dies at t=3s and
    # rejoins at t=8s (times in ms)
    churn = [WorkerEvent(1500.0, 3, "degrade", 4.0),
             WorkerEvent(3000.0, 7, "leave"),
             WorkerEvent(8000.0, 7, "join"),
             WorkerEvent(9000.0, 3, "restore")]

    print(f"{'policy':<12} {'p50':>8} {'p95':>8} {'p99':>8} "
          f"{'queue':>8} {'waste':>7} {'replans':>7}")
    for policy in ("dedicated", "fractional", "uncoded"):
        srcs = [PoissonProcess(m, rate=0.004, seed=2) for m in range(sc.M)]
        cfg = StreamConfig(
            policy=policy,
            replan=ReplanPolicy(mode="incremental",
                                use_sca=(policy != "uncoded")),
            backend=BackendConfig(numerics="verify"), rng=0)
        ex = StreamingExecutor(sc, srcs, config=cfg, churn=churn)
        s = ex.run(max_tasks=150).summary()
        assert s.get("decode_ok_rate", 1.0) == 1.0, "decode verification failed"
        print(f"{policy:<12} {s['sojourn_p50']:8.1f} {s['sojourn_p95']:8.1f} "
              f"{s['sojourn_p99']:8.1f} {s['queue_wait_mean']:8.1f} "
              f"{s['wasted_fraction']:7.2f} {s['replans']:7.0f}")
    print("(times in ms; waste = redundant coded rows / useful rows; "
          "all decodes verified)")


if __name__ == "__main__":
    main()
